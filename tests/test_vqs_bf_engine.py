"""VQS-BF accelerator engines + the admission-mode dispatch bugfix.

Covers the ISSUE 9 acceptance paths: bit-parity of scan/pallas with the
event-driven ``core/vqs_bf.py`` oracle on synthetic traces AND the
google_like_50 CSV fixture, scan-vs-reference equivalence on random
streams (fault planes included), the paper's Section VI delay claim
(VQS-BF tail well below VQS tail on shared streams), chunked/state
threading, capacity planning via ``estimate_capacity(policy="vqs-bf")``
and the ``AdmissionController.policy`` dispatch (all three documented
modes distinct + unknown value raises)."""
import os

import jax
import numpy as np
import pytest

from repro.cluster.admission import AdmissionController, PendingJob
from repro.core import VQSBF, load_trace_csv, simulate_trace
from repro.core.engine import (make_streams, run_policy, run_policy_streams,
                               streams_from_trace, Workload)
from repro.core.engine.vqs_bf import (_run_vqs_bf_reference_streams,
                                      run_vqs_bf_streams)

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "google_like_50.csv")

# vqs-bf serves ONE placement per work step (largest-fit pops depend on
# the residual the previous pop left), so the bound is sized to the
# per-slot burst, not to A_max
WORK = 64


def _random_trace(seed, T, N, grid=64):
    rng = np.random.default_rng(seed)
    slots = np.sort(rng.integers(0, T, N))
    sizes = rng.integers(1, grid, N) / float(grid)
    durs = rng.integers(1, 60, N)
    return slots, sizes, durs


def _uniform_sampler(lo, hi):
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=lo, maxval=hi)
    return sampler


# ---------------------------------------------------------------------------
# trace-driven parity with the event-driven engine (the oracle bridge)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["reference", "scan", "pallas"])
@pytest.mark.parametrize("seed,J,L", [(0, 3, 5), (7, 5, 12), (3, 2, 1)])
def test_vqs_bf_engine_bitmatches_numpy_on_trace(engine, seed, J, L):
    """run_policy_streams(policy="vqs-bf") == simulate_trace(VQSBF(J))
    queue trajectory, slot for slot, on grid-sized jobs."""
    T, N = 400, 60 * L
    slots, sizes, durs = _random_trace(seed, T, N)
    ref = simulate_trace(VQSBF(J=J), L=L, arrival_slots=slots, sizes=sizes,
                         durations=durs, horizon=T, seed=0, record_every=1)
    st = streams_from_trace(slots, sizes, durs, horizon=T)
    res = run_policy_streams(st, policy="vqs-bf", engine=engine, J=J, L=L,
                             K=1 << J, Qcap=2048,
                             A_max=int(st.sizes.shape[1]), work_steps=WORK)
    assert int(res.truncated) == 0
    assert int(res.dropped) == 0
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  ref.queue_lens)
    assert int(res.departed[-1]) == ref.departed


@pytest.mark.parametrize("engine", ["scan", "pallas"])
def test_vqs_bf_google50_trace_bitmatches_numpy(engine):
    """The collapsed google_like_50 fixture replays through the
    accelerated engines and reproduces the numpy oracle exactly."""
    trace = load_trace_csv(FIXTURE, slot_seconds=10.0)
    sizes = np.maximum(trace.cpu, trace.mem)
    T = int(trace.arrival_slots[-1]) + 80
    ref = simulate_trace(VQSBF(J=3), L=8, arrival_slots=trace.arrival_slots,
                         sizes=sizes, durations=trace.durations, horizon=T,
                         seed=0, record_every=1)
    st = streams_from_trace(trace, horizon=T)
    res = run_policy_streams(st, policy="vqs-bf", engine=engine, J=3, L=8,
                             K=8, Qcap=256, A_max=int(st.sizes.shape[1]),
                             work_steps=WORK)
    assert int(res.truncated) == 0 and int(res.dropped) == 0
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  ref.queue_lens)
    assert int(res.departed[-1]) == ref.departed > 0


# ---------------------------------------------------------------------------
# scan vs reference on random streams (fault planes included)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,lam,J,fault_rate",
                         [(0, 0.3, 2, 0.0), (1, 1.0, 4, 0.0),
                          (4, 1.2, 3, 0.02)])
def test_vqs_bf_scan_bitmatches_reference_engine(seed, lam, J, fault_rate):
    st = make_streams(jax.random.PRNGKey(seed), lam, 0.02,
                      _uniform_sampler(0.05, 0.9), L=6, K=40, A_max=6,
                      horizon=600, fault_rate=fault_rate,
                      repair_rate=0.2 if fault_rate else 1.0)
    kw = dict(J=J, L=6, K=40, Qcap=512, A_max=6)
    ref = _run_vqs_bf_reference_streams(st, **kw)
    scn = run_vqs_bf_streams(st, work_steps=WORK, **kw)
    assert int(scn.truncated) == 0
    for field in ("queue_len", "occupancy", "departed", "dropped",
                  "preempted", "requeued", "lost"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, field)),
                                      np.asarray(getattr(scn, field)))
    if fault_rate:
        assert int(ref.preempted) > 0
        assert int(ref.preempted) == int(ref.requeued) + int(ref.lost)


def test_vqs_bf_truncation_counted_not_silent():
    """A starved work bound must report itself via ``truncated``."""
    st = make_streams(jax.random.PRNGKey(2), 3.0, 0.01,
                      _uniform_sampler(0.05, 0.3), L=8, K=32, A_max=8,
                      horizon=300)
    res = run_vqs_bf_streams(st, J=3, L=8, K=32, Qcap=512, A_max=8,
                             work_steps=1)
    assert int(res.truncated) > 0


# ---------------------------------------------------------------------------
# the paper's Section VI claim: VQS throughput, BF-like delay
# ---------------------------------------------------------------------------
def test_vqs_bf_tail_well_below_vqs_tail_on_shared_streams():
    """Same pre-generated streams, stable load: VQS-BF's backfilled queue
    sits far below plain VQS's (the Theorem 4 delay motivation)."""
    st = make_streams(jax.random.PRNGKey(3), 0.3, 0.05,
                      _uniform_sampler(0.05, 0.9), L=6, K=40, A_max=6,
                      horizon=1000)
    kw = dict(J=3, L=6, K=40, Qcap=2048, A_max=6)
    vqs = run_policy_streams(st, policy="vqs", engine="scan", **kw)
    vqsbf = run_policy_streams(st, policy="vqs-bf", engine="scan",
                               work_steps=WORK, **kw)
    assert int(vqs.truncated) == 0 and int(vqsbf.truncated) == 0
    tail_vqs = float(np.mean(np.asarray(vqs.queue_len)[200:]))
    tail_bf = float(np.mean(np.asarray(vqsbf.queue_len)[200:]))
    assert tail_bf < 0.6 * tail_vqs
    assert int(np.asarray(vqsbf.queue_len).max()) \
        <= int(np.asarray(vqs.queue_len).max())


# ---------------------------------------------------------------------------
# stack inheritance: chunked state threading + capacity planning
# ---------------------------------------------------------------------------
def test_vqs_bf_chunked_sweep_bitmatches_one_shot(tmp_path):
    st = make_streams(jax.random.PRNGKey(5), 1.0, 0.05,
                      _uniform_sampler(0.05, 0.9), L=4, K=8, A_max=4,
                      horizon=240)
    kw = dict(J=3, L=4, K=8, Qcap=64, A_max=4, work_steps=32)
    one = run_policy_streams(st, policy="vqs-bf", engine="scan", **kw)
    chk = run_policy_streams(st, policy="vqs-bf", engine="scan", chunk=60,
                             checkpoint_dir=str(tmp_path), **kw)
    for field in ("queue_len", "occupancy", "departed", "dropped",
                  "truncated"):
        np.testing.assert_array_equal(np.asarray(getattr(one, field)),
                                      np.asarray(getattr(chk, field)))


def test_estimate_capacity_accepts_vqs_bf():
    from repro.serving.engine import estimate_capacity
    out = estimate_capacity(4, 1.0, 20.0, _uniform_sampler(0.05, 0.9),
                            ensembles=4, horizon=200, policy="vqs-bf",
                            J=3, K=8, Qcap=64, A_max=4, work_steps=32)
    assert out["policy"] == "vqs-bf"
    assert out["truncated"] == 0
    assert out["slots_simulated"] == 4 * 200


# ---------------------------------------------------------------------------
# the bugfix: AdmissionController dispatches on its policy field
# ---------------------------------------------------------------------------
def _crafted_refill(policy):
    """Fill one replica, queue a crafted mix, free it, serve the queue."""
    ac = AdmissionController(1, policy=policy, J=3)
    big = PendingJob(0, 1.0)
    assert ac.admit([big]) == [(0, 0)]
    ac.admit([PendingJob(1, 0.9), PendingJob(2, 0.45), PendingJob(3, 0.30),
              PendingJob(4, 0.28), PendingJob(5, 0.26),
              PendingJob(6, 0.10)])
    ac.release(0, big.size)
    return [rid for rid, _ in ac.refill(0)]


def test_admission_policy_modes_dispatch_differently():
    bf = _crafted_refill("bf")
    vqsbf = _crafted_refill("vqs-bf")
    fifo = _crafted_refill("fifo")
    # bf grabs the largest fitting request first
    assert bf[0] == 1
    # fifo serves the head and then blocks on the 0.9 head-of-line gap
    assert fifo == [1]
    # vqs-bf follows its max-weight configuration, not pure size greed
    assert vqsbf != bf
    assert vqsbf != fifo


def test_admission_unknown_policy_raises():
    with pytest.raises(ValueError, match="bf, vqs-bf, fifo"):
        AdmissionController(2, policy="typo")


def test_admission_vqs_bf_renews_config_at_empty_epochs():
    ac = AdmissionController(1, policy="vqs-bf", J=3)
    assert ac._active_cfg[0] is None
    job = PendingJob(0, 0.9)
    ac.admit([job])                      # replica busy, nothing queued
    ac.admit([PendingJob(1, 0.45)])      # doesn't fit -> queues
    ac.release(0, job.size)              # replica empties
    placed = ac.refill(0)                # renewal happens here
    assert ac._active_cfg[0] is not None
    assert np.asarray(ac._active_cfg[0]).sum() > 0   # a K_RED row
    assert (1, 0) in placed


def test_admission_bf_mode_unchanged_by_dispatch():
    """policy="bf" keeps the exact legacy BF-S behaviour (largest fitting
    first, FIFO among equal sizes)."""
    ac = AdmissionController(1, policy="bf", J=3)
    big = PendingJob(0, 1.0)
    ac.admit([big])
    ac.admit([PendingJob(1, 0.5), PendingJob(2, 0.5), PendingJob(3, 0.4)])
    ac.release(0, big.size)
    assert [rid for rid, _ in ac.refill(0)] == [1, 2]
