"""rho* LP (Eq. 4), Lemma 1, Theorem 1 convergence, Proposition 2 example."""
import numpy as np
import pytest

# deselected by the fast tier-1 lane (-m "not slow"); CI runs
# the full suite
pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.distributions import Discrete, Uniform
from repro.core.stability import (enumerate_configs, maximal_configs,
                                  rho_bounds, rho_star_discrete,
                                  rho_star_upper_bound)


def test_fig3a_example():
    # sizes 0.4/0.6 equal prob, 1 server: config (1,1) feasible => rho* = 2
    r = rho_star_discrete(np.array([0.4, 0.6]), np.array([0.5, 0.5]), L=1)
    assert r == pytest.approx(2.0, rel=1e-6)


def test_fig3b_example():
    # cap 10 / sizes 2,5 (0.2/0.5), probs (2/3, 1/3): paper shows
    # lambda < 4/9 mu1 + 5/9 mu2 supportable -> rho* = 10/3
    r = rho_star_discrete(np.array([0.2, 0.5]), np.array([2 / 3, 1 / 3]), L=1)
    assert r == pytest.approx(10 / 3, rel=1e-6)


def test_proposition2_example():
    """Sizes 1/2 +- eps: true rho* = 2 (config (1,1)); upper-rounding both
    types to a partition with sup >= 1/2+eps can pack only (2,0)/(0,1) ->
    4/3 = (2/3) rho*. The LP reproduces both numbers."""
    eps = 0.01
    r_true = rho_star_discrete(np.array([0.5 - eps, 0.5 + eps]),
                               np.array([0.5, 0.5]), L=1)
    assert r_true == pytest.approx(2.0, rel=1e-6)
    # oblivious upper-rounded system: both sizes round up so that two
    # "small" jobs still fit but small+large do not
    r_rounded = rho_star_discrete(np.array([0.5, 0.5 + eps]),
                                  np.array([0.5, 0.5]), L=1)
    assert r_rounded == pytest.approx(4 / 3, rel=1e-4)
    assert r_rounded == pytest.approx(2 / 3 * r_true, rel=1e-4)


def test_lemma1_upper_bound():
    d = Uniform(0.1, 0.9)
    assert rho_star_upper_bound(d, 5) == pytest.approx(5 / 0.5)


def test_scaling_in_servers():
    sizes, probs = np.array([0.3, 0.5]), np.array([0.5, 0.5])
    r1 = rho_star_discrete(sizes, probs, L=1)
    r4 = rho_star_discrete(sizes, probs, L=4)
    assert r4 == pytest.approx(4 * r1, rel=1e-6)


def test_theorem1_convergence():
    """Upper-rounded bound increases, lower-rounded decreases, and they
    bracket L/E[R]-ish truth as the quantile partition refines."""
    d = Uniform(0.2, 0.9)
    ups, los = [], []
    for n in (0, 1, 2):
        up, lo = rho_bounds(d, n, L=1)
        ups.append(up)
        los.append(lo)
    assert ups == sorted(ups)                 # nondecreasing
    assert los == sorted(los, reverse=True)   # nonincreasing
    assert ups[-1] <= los[-1]
    assert los[-1] - ups[-1] < los[0] - ups[0]


def test_enumerate_configs_counts():
    sizes = np.array([32768, 21845], dtype=np.int64)   # 0.5, 1/3
    confs = enumerate_configs(sizes)
    # k1 in 0..2, k2 in 0..3 subject to k1/2 + k2/3 <= 1
    feasible = {(k1, k2) for k1 in range(3) for k2 in range(4)
                if k1 * 32768 + k2 * 21845 <= 65536}
    assert set(map(tuple, confs)) == feasible
    maxi = maximal_configs(confs, sizes)
    assert set(map(tuple, maxi)) == {(2, 0), (1, 1), (0, 3)}


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.15, 1.0), min_size=1, max_size=4, unique=True),
       st.integers(1, 4))
def test_rho_star_bounds_random(sizes, L):
    """L <= rho* <= L / mean(R) for any discrete distribution."""
    sizes = np.asarray(sizes)
    probs = np.full(len(sizes), 1.0 / len(sizes))
    r = rho_star_discrete(sizes, probs, L=L)
    mean = float(np.dot(sizes, probs))
    assert r >= L - 1e-6
    assert r <= L / mean + 1e-4 + L * 1e-3  # grid-rounding slack
