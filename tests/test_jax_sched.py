"""JAX scheduling engine: agreement with the event-driven engine and the
Pallas kernel; Monte-Carlo vmap path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BFJS, PartitionI, RES, ServiceModel, Uniform, \
    simulate, to_grid
from repro.core.jax_sched import (best_fit_place, best_fit_server,
                                  make_streams, max_weight_config_jax,
                                  monte_carlo_bfjs, run_bfjs,
                                  run_bfjs_streams, vq_type_of)
from repro.core.partition import k_red, max_weight_config


def test_best_fit_place_matches_pallas_ref():
    from repro.kernels.best_fit.ref import best_fit_ref
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    resid = jax.random.uniform(k1, (32,))
    sizes = jax.random.uniform(k2, (16,), minval=0.05, maxval=0.7)
    a1, r1 = best_fit_place(resid, sizes)
    a2, r2 = best_fit_ref(resid, sizes)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(r1, r2, rtol=1e-6)


def test_best_fit_server_rejects():
    assert int(best_fit_server(jnp.array([0.2, 0.1]), jnp.asarray(0.5))) == -1
    assert int(best_fit_server(jnp.array([0.6, 0.5]), jnp.asarray(0.5))) == 1


def test_vq_type_of_matches_partition():
    for J in (2, 4, 6):
        part = PartitionI(J)
        sizes = np.linspace(0.012, 1.0, 97)
        ints = to_grid(sizes)
        expect = part.type_of(ints)
        got = np.asarray(vq_type_of(jnp.asarray(sizes), J))
        agree = (got == expect).mean()
        assert agree > 0.95, (J, agree)  # float/grid boundary slack


@pytest.mark.parametrize("J", [2, 3, 6, 10])
def test_vq_type_of_matches_partition_exactly_on_full_grid(J):
    """Exact parity with PartitionI.type_of_scalar on EVERY grid size,
    including exact powers of two and the size <= 2^-J tail."""
    part = PartitionI(J)
    g = np.arange(1, RES + 1, dtype=np.int64)
    sizes = (g.astype(np.float64) / RES).astype(np.float32)  # exact in f32
    expect = part.type_of(g)
    got = np.asarray(vq_type_of(jnp.asarray(sizes), J))
    np.testing.assert_array_equal(got, expect)
    # spot-check the scalar API on the boundaries the float path fudged
    for m in range(J):
        assert int(vq_type_of(jnp.float32(2.0 ** -m), J)) \
            == part.type_of_scalar(RES >> m)
    assert int(vq_type_of(jnp.float32(2.0 ** -J), J)) == 2 * J - 1


def test_max_weight_config_jax_matches_numpy():
    for J in (2, 4):
        q = np.random.default_rng(0).integers(0, 100, size=2 * J)
        i_np, c_np = max_weight_config(J, q)
        i_j, c_j = max_weight_config_jax(J, jnp.asarray(q))
        w = k_red(J) @ q
        assert w[int(i_j)] == w.max()
        np.testing.assert_array_equal(np.asarray(c_j), k_red(J)[int(i_j)])


def test_run_bfjs_stable_vs_overloaded():
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.9)

    stable = run_bfjs(jax.random.PRNGKey(0), lam=0.06, mu=0.01,
                      sampler=sampler, L=5, K=12, Qcap=512, A_max=6,
                      horizon=15_000)
    over = run_bfjs(jax.random.PRNGKey(0), lam=0.25, mu=0.01,
                    sampler=sampler, L=5, K=12, Qcap=512, A_max=6,
                    horizon=15_000)
    q_s = float(stable.queue_len[-3000:].mean())
    q_o = float(over.queue_len[-3000:].mean())
    assert q_s < 30
    assert q_o > 5 * q_s       # overloaded queue blows up
    assert int(stable.dropped) == 0


def _uniform_sampler(lo, hi):
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=lo, maxval=hi)
    return sampler


@pytest.mark.parametrize("seed,lam", [(0, 0.5), (1, 1.5), (2, 3.0)])
def test_scan_engine_bitmatches_reference_engine(seed, lam):
    """The branch-free engine on pre-generated streams reproduces the seed
    nested-loop engine trajectory bit-for-bit (same key)."""
    sampler = _uniform_sampler(0.05, 0.5)
    kw = dict(L=6, K=8, Qcap=64, A_max=6, horizon=800)
    ref = run_bfjs(jax.random.PRNGKey(seed), lam, 0.02, sampler,
                   engine="reference", **kw)
    new = run_bfjs(jax.random.PRNGKey(seed), lam, 0.02, sampler,
                   engine="scan", **kw)
    assert int(new.truncated) == 0
    np.testing.assert_array_equal(np.asarray(new.queue_len),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(new.departed),
                                  np.asarray(ref.departed))
    np.testing.assert_array_equal(np.asarray(new.occupancy),
                                  np.asarray(ref.occupancy))
    assert int(new.dropped) == int(ref.dropped)


def test_streams_bitmatch_reference_inloop_draws():
    """make_streams replays the reference engine's exact per-slot key chain:
    batched pre-generation == the in-loop draws, bitwise."""
    lam, mu, L, K, A_max, T = 1.5, 0.01, 4, 6, 8, 60
    sampler = _uniform_sampler(0.05, 0.5)
    key = jax.random.PRNGKey(42)
    st = make_streams(key, lam, mu, sampler, L=L, K=K, A_max=A_max,
                      horizon=T)
    from repro.core.jax_sched import _geometric
    k = key
    for t in range(T):
        k, _, k_n, k_sizes, k_dur = jax.random.split(k, 5)
        n = jnp.minimum(jax.random.poisson(k_n, lam), A_max)
        assert int(st.n[t]) == int(n)
        np.testing.assert_array_equal(np.asarray(st.sizes[t]),
                                      np.asarray(sampler(k_sizes, A_max)))
        np.testing.assert_array_equal(
            np.asarray(st.durs[t]),
            np.asarray(_geometric(k_dur, mu, (L * K + A_max,))))


def test_scan_engine_truncation_is_flagged_not_silent():
    """A too-small work list must be reported via `truncated`, and a
    sufficient one must reproduce the reference exactly."""
    sampler = _uniform_sampler(0.05, 0.2)   # many small jobs per server
    kw = dict(L=4, K=12, Qcap=64, A_max=8)
    streams = make_streams(jax.random.PRNGKey(5), 4.0, 0.05, sampler,
                           L=4, K=12, A_max=8, horizon=400)
    tiny = run_bfjs_streams(streams, Qcap=64, L=4, K=12, A_max=8,
                            work_steps=1)
    ample = run_bfjs_streams(streams, Qcap=64, L=4, K=12, A_max=8,
                             work_steps=24)
    assert int(tiny.truncated) > 0
    assert int(ample.truncated) == 0
    ref = run_bfjs(jax.random.PRNGKey(5), 4.0, 0.05, sampler,
                   engine="reference", horizon=400, **kw)
    np.testing.assert_array_equal(np.asarray(ample.queue_len),
                                  np.asarray(ref.queue_len))


def test_monte_carlo_engines_agree():
    """vmapped scan engine == gridded Pallas kernel (interpret) == reference,
    member by member, on shared streams."""
    sampler = _uniform_sampler(0.1, 0.6)
    kw = dict(L=4, K=6, Qcap=48, A_max=5, horizon=150)
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    ref = monte_carlo_bfjs(keys, 1.0, 0.03, sampler, engine="reference", **kw)
    scan = monte_carlo_bfjs(keys, 1.0, 0.03, sampler, engine="scan", **kw)
    pal = monte_carlo_bfjs(keys, 1.0, 0.03, sampler, engine="pallas", **kw)
    assert int(np.asarray(scan.truncated).sum()) == 0
    for res in (scan, pal):
        np.testing.assert_array_equal(np.asarray(res.queue_len),
                                      np.asarray(ref.queue_len))
        np.testing.assert_array_equal(np.asarray(res.departed),
                                      np.asarray(ref.departed))
        np.testing.assert_array_equal(np.asarray(res.dropped),
                                      np.asarray(ref.dropped))


def test_jax_engine_agrees_with_numpy_engine_distributionally():
    """Same workload, both engines: tail queue means within 2x (they use
    different RNG streams; the regime must match)."""
    lam, mu, L = 0.07, 0.01, 5

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.9)

    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    jres = monte_carlo_bfjs(keys, lam, mu, sampler, L=L, K=16, Qcap=512,
                            A_max=6, horizon=12_000)
    jq = float(jres.queue_len[:, -3000:].mean())

    nres = simulate(BFJS(), L=L, lam=lam, dist=Uniform(0.1, 0.9),
                    service=ServiceModel("geometric", 1 / mu),
                    horizon=12_000, seed=0)
    nq = max(nres.mean_queue_tail, 0.3)
    assert jq / nq < 3.0 and nq / max(jq, 0.3) < 3.0, (jq, nq)
