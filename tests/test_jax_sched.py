"""JAX scheduling engine: agreement with the event-driven engine and the
Pallas kernel; Monte-Carlo vmap path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BFJS, PartitionI, ServiceModel, Uniform, simulate, to_grid
from repro.core.jax_sched import (best_fit_place, best_fit_server,
                                  max_weight_config_jax, monte_carlo_bfjs,
                                  run_bfjs, vq_type_of)
from repro.core.partition import k_red, max_weight_config


def test_best_fit_place_matches_pallas_ref():
    from repro.kernels.best_fit.ref import best_fit_ref
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    resid = jax.random.uniform(k1, (32,))
    sizes = jax.random.uniform(k2, (16,), minval=0.05, maxval=0.7)
    a1, r1 = best_fit_place(resid, sizes)
    a2, r2 = best_fit_ref(resid, sizes)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(r1, r2, rtol=1e-6)


def test_best_fit_server_rejects():
    assert int(best_fit_server(jnp.array([0.2, 0.1]), jnp.asarray(0.5))) == -1
    assert int(best_fit_server(jnp.array([0.6, 0.5]), jnp.asarray(0.5))) == 1


def test_vq_type_of_matches_partition():
    for J in (2, 4, 6):
        part = PartitionI(J)
        sizes = np.linspace(0.012, 1.0, 97)
        ints = to_grid(sizes)
        expect = part.type_of(ints)
        got = np.asarray(vq_type_of(jnp.asarray(sizes), J))
        agree = (got == expect).mean()
        assert agree > 0.95, (J, agree)  # float/grid boundary slack


def test_max_weight_config_jax_matches_numpy():
    for J in (2, 4):
        q = np.random.default_rng(0).integers(0, 100, size=2 * J)
        i_np, c_np = max_weight_config(J, q)
        i_j, c_j = max_weight_config_jax(J, jnp.asarray(q))
        w = k_red(J) @ q
        assert w[int(i_j)] == w.max()
        np.testing.assert_array_equal(np.asarray(c_j), k_red(J)[int(i_j)])


def test_run_bfjs_stable_vs_overloaded():
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.9)

    stable = run_bfjs(jax.random.PRNGKey(0), lam=0.06, mu=0.01,
                      sampler=sampler, L=5, K=12, Qcap=512, A_max=6,
                      horizon=15_000)
    over = run_bfjs(jax.random.PRNGKey(0), lam=0.25, mu=0.01,
                    sampler=sampler, L=5, K=12, Qcap=512, A_max=6,
                    horizon=15_000)
    q_s = float(stable.queue_len[-3000:].mean())
    q_o = float(over.queue_len[-3000:].mean())
    assert q_s < 30
    assert q_o > 5 * q_s       # overloaded queue blows up
    assert int(stable.dropped) == 0


def test_jax_engine_agrees_with_numpy_engine_distributionally():
    """Same workload, both engines: tail queue means within 2x (they use
    different RNG streams; the regime must match)."""
    lam, mu, L = 0.07, 0.01, 5

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.9)

    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    jres = monte_carlo_bfjs(keys, lam, mu, sampler, L=L, K=16, Qcap=512,
                            A_max=6, horizon=12_000)
    jq = float(jres.queue_len[:, -3000:].mean())

    nres = simulate(BFJS(), L=L, lam=lam, dist=Uniform(0.1, 0.9),
                    service=ServiceModel("geometric", 1 / mu),
                    horizon=12_000, seed=0)
    nq = max(nres.mean_queue_tail, 0.3)
    assert jq / nq < 3.0 and nq / max(jq, 0.3) < 3.0, (jq, nq)
