"""End-to-end behaviour tests for the paper's system.

1. Train a small LM for a few dozen steps: loss must drop substantially.
2. Serve it with batched requests under BF-J/S admission: all complete.
3. Lower + compile a sharded train step on the host mesh (mini dry-run).
4. The full 512-chip dry-run artifacts are checked in test_infra.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.train.trainer import Trainer, TrainerConfig


def test_end_to_end_training_reduces_loss(tmp_path):
    cfg = get_smoke_config("llama3-8b").with_(num_layers=2)
    tcfg = TrainerConfig(seq_len=64, global_batch=8, steps=40,
                         ckpt_every=100, ckpt_dir=str(tmp_path),
                         log_every=100, peak_lr=1e-3, warmup=5)
    tr = Trainer(cfg, tcfg)
    state = tr.run(tr.init_state())
    hist = state.metrics["loss_history"]
    first, last = np.mean(hist[:5]), np.mean(hist[-5:])
    assert last < first * 0.9, (first, last)


def test_end_to_end_train_then_serve(tmp_path):
    cfg = get_smoke_config("llama3-8b")
    tcfg = TrainerConfig(seq_len=32, global_batch=4, steps=6, ckpt_every=6,
                         ckpt_dir=str(tmp_path), log_every=100)
    tr = Trainer(cfg, tcfg)
    state = tr.run(tr.init_state())
    params = jax.tree.map(np.asarray, state.params)
    eng = ServingEngine(cfg, params, num_replicas=2, b_slots=2, c_max=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, size=6)
                    .astype(np.int32), max_new=5) for i in range(6)]
    eng.submit(reqs)
    done = eng.run(max_steps=300)
    assert len(done) == 6
    assert all(len(r.out) == 5 for r in done)


def test_sharded_train_step_compiles_on_host_mesh():
    """Mini dry-run: the exact pjit/jit pipeline of launch/dryrun.py, on the
    host's devices (1 CPU here, 256/512 in the real sweep)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import (batch_specs, fit_spec_tree,
                                            param_specs, to_named)
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import (input_specs, make_optimizer,
                                    make_train_step)
    from repro.models.config import ShapeConfig

    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("mini", "train", 64, 4)
    mesh = make_host_mesh()
    specs = input_specs(cfg, shape)
    with mesh:
        p_sh = to_named(mesh, param_specs(specs["params"], cfg, mesh))
        o_sh = type(specs["opt_state"])(
            step=NamedSharding(mesh, P()),
            mu=to_named(mesh, param_specs(specs["opt_state"].mu, cfg, mesh)),
            nu=to_named(mesh, param_specs(specs["opt_state"].nu, cfg, mesh)))
        b_sh = to_named(mesh, fit_spec_tree(
            mesh, batch_specs(cfg, mesh, "train"), specs["batch"]))
        step = make_train_step(cfg, make_optimizer(cfg))
        lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                          donate_argnums=(0, 1)).lower(
            specs["params"], specs["opt_state"], specs["batch"])
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    assert cost.get("flops", 0) > 0


def test_decode_greedy_is_deterministic():
    cfg = get_smoke_config("mamba2-130m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    caches = M.init_cache(cfg, 1, 16)
    tok = jnp.ones((1, 1), jnp.int32)
    outs = []
    for trial in range(2):
        c = jax.tree.map(jnp.copy, caches)
        t = tok
        seq = []
        for i in range(5):
            logits, c = M.decode_step(params, cfg, t, jnp.asarray(i), c)
            t = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
            seq.append(int(t[0, 0]))
        outs.append(seq)
    assert outs[0] == outs[1]
