"""Serving engine + admission control + gang scheduler (the paper's
algorithms as first-class cluster features)."""
import jax
import numpy as np
import pytest

from repro.cluster.admission import AdmissionController, PendingJob
from repro.cluster.gang import GangScheduler, TrainJob
from repro.configs import get_smoke_config
from repro.core.quantize import RES
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine, estimate_capacity


def test_admission_best_fit_order():
    ac = AdmissionController(num_replicas=2)
    placed = ac.admit([PendingJob(0, 0.5), PendingJob(1, 0.4),
                       PendingJob(2, 0.6), PendingJob(3, 0.6)])
    # 0.5 -> r0; 0.4 -> r0 (tightest, residual .5 < 1.0); 0.6 -> r1; 0.6 queues
    assert placed == [(0, 0), (1, 0), (2, 1)]
    assert ac.queue_len() == 1
    assert (ac.residual >= 0).all()


def test_admission_refill_largest_first():
    ac = AdmissionController(num_replicas=1)
    ac.admit([PendingJob(0, 0.9)])
    ac.admit([PendingJob(1, 0.5), PendingJob(2, 0.3), PendingJob(3, 0.2)])
    assert ac.queue_len() == 3
    ac.release(0, PendingJob(0, 0.9).size)
    placed = ac.refill(0)
    # BF-S: largest fitting first: 0.5 then 0.3 then 0.2
    assert [rid for rid, _ in placed] == [1, 2, 3]
    assert ac.queue_len() == 0


def test_admission_vq_accounting():
    ac = AdmissionController(num_replicas=1, J=4)
    ac.admit([PendingJob(0, 0.95)])          # fills the replica
    ac.admit([PendingJob(1, 0.6), PendingJob(2, 0.3)])
    cfgrow = ac.max_weight_config()
    assert cfgrow.sum() > 0                   # some configuration is selected
    assert ac._vq_sizes.sum() == 2


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-130m"])
def test_serving_engine_completes(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, num_replicas=2, b_slots=3, c_max=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=rng.integers(4, 20)).astype(np.int32),
                    max_new=int(rng.integers(4, 12)))
            for i in range(10)]
    eng.submit(reqs)
    done = eng.run(max_steps=600)
    assert len(done) == 10
    for r in done:
        assert len(r.out) >= 1
    # paper capacity constraint held throughout
    assert (eng.admission.residual >= 0).all()
    assert (eng.admission.residual <= RES).all()


def test_estimate_capacity_separates_under_from_overprovisioned():
    """The jax_sched-backed what-if planner: a fleet double the offered load
    keeps a short queue and drops nothing; a fleet at a fraction of it
    saturates."""
    kw = dict(ensembles=3, horizon=600, K=8, Qcap=128, A_max=6)
    lam, mean_slots = 0.4, 40.0          # offered capacity-load ~ 4.4
    big = estimate_capacity(10, lam, mean_slots, **kw)
    small = estimate_capacity(2, lam, mean_slots, **kw)
    assert big["truncated"] == 0
    assert big["dropped"] == 0
    assert big["mean_tail_queue"] < 5
    assert small["mean_tail_queue"] > 10 * max(big["mean_tail_queue"], 0.1)


def test_serving_queue_drains_in_arrival_waves():
    cfg = get_smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, num_replicas=1, b_slots=2, c_max=48)
    rng = np.random.default_rng(1)
    for wave in range(3):
        reqs = [Request(rid=wave * 10 + i,
                        prompt=rng.integers(1, 64, size=8).astype(np.int32),
                        max_new=4) for i in range(4)]
        eng.submit(reqs)
        for _ in range(30):
            eng.step()
    eng.run(max_steps=400)
    assert len(eng.completed) == 12
    assert eng.admission.queue_len() == 0


def test_gang_recovers_from_failures():
    gs = GangScheduler(num_pods=3, seed=1)
    gs.submit([TrainJob(jid=i, hbm_frac=0.4, steps_total=15)
               for i in range(6)])
    for t in range(80):
        gs.tick()
        if t == 8:
            victims = gs.fail_pod(1)
            assert victims  # something was actually running there
    gs.cluster.check_invariants()
    assert all(j.steps_done >= j.steps_total for j in gs.jobs.values())
    assert any(j.restarts > 0 for j in gs.jobs.values())
