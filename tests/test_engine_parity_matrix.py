"""Cross-engine parity matrix: every (policy, engine) cell must reproduce
the policy's reference trajectory on a shared seed.

One parametrized sweep over policy x engine so every future engine lands
with parity enforced by collection, not convention: registering a policy
(or growing ENGINES) grows the matrix automatically, and a cell that
cannot run is a FAILURE, not a skip.  The uncollapsed google_like_50 CSV
fixture closes the loop for the trace-driven path (real-trace columns ->
streams -> scan == pallas == oracle)."""
import os

import jax
import numpy as np
import pytest

from repro.core import load_trace_csv
from repro.core.engine import (ENGINES, Workload, available_policies,
                               run_policy, run_policy_streams,
                               streams_from_trace)

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "google_like_50.csv")


def _scalar_sampler(key, n):
    return jax.random.uniform(key, (n,), minval=0.05, maxval=0.5)


def _vec_sampler(key, n):
    return jax.random.uniform(key, (n, 2), minval=0.05, maxval=0.5)


#: policy -> (Workload, engine-agnostic config).  K >= 2^J for VQS (the
#: packing bound), generous work_steps everywhere so truncated == 0 and
#: the bit-match contract applies end to end.
MATRIX = {
    "bfjs": (Workload(lam=1.2, mu=0.05, sampler=_scalar_sampler),
             dict(L=4, K=6, Qcap=64, A_max=5, horizon=150)),
    "vqs": (Workload(lam=1.0, mu=0.05, sampler=_scalar_sampler),
            dict(L=4, K=8, Qcap=64, A_max=5, horizon=150, J=3)),
    "bfjs-mr": (Workload(lam=0.5, mu=0.05, sampler=_vec_sampler,
                         num_resources=2, capacity=(1.0, 0.75)),
                dict(L=4, K=8, Qcap=64, A_max=5, horizon=150,
                     work_steps=24)),
    # vqs-bf places ONE job per work step (largest-fit pops can't batch),
    # so its bound is sized to the burst, not to A_max
    "vqs-bf": (Workload(lam=1.0, mu=0.05, sampler=_scalar_sampler),
               dict(L=4, K=8, Qcap=64, A_max=5, horizon=150, J=3,
                    work_steps=48)),
}


def test_matrix_covers_every_registered_policy():
    assert set(MATRIX) == set(available_policies()), (
        "every registered policy must appear in the parity matrix — add "
        "its Workload/config row here when registering a new policy")


@pytest.fixture(scope="module")
def reference_runs():
    """One reference trajectory per policy, computed once and shared."""
    key = jax.random.PRNGKey(42)
    return {policy: run_policy(wl, key, policy=policy, engine="reference",
                               **{k: v for k, v in cfg.items()
                                  if k != "work_steps"})
            for policy, (wl, cfg) in MATRIX.items()}


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("policy", sorted(MATRIX))
def test_policy_engine_parity(policy, engine, reference_runs):
    wl, cfg = MATRIX[policy]
    res = run_policy(wl, jax.random.PRNGKey(42), policy=policy,
                     engine=engine, **cfg)
    ref = reference_runs[policy]
    assert int(np.asarray(res.truncated).sum()) == 0
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(res.occupancy),
                                  np.asarray(ref.occupancy))
    np.testing.assert_array_equal(np.asarray(res.departed),
                                  np.asarray(ref.departed))
    np.testing.assert_array_equal(np.asarray(res.dropped),
                                  np.asarray(ref.dropped))


# ---------------------------------------------------------------------------
# trace-driven parity: the uncollapsed google_like_50 CSV fixture
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def google50_streams():
    trace = load_trace_csv(FIXTURE, slot_seconds=10.0)
    return streams_from_trace(trace, collapse=False, num_resources=2)


@pytest.mark.parametrize("engine", ["scan", "pallas"])
def test_google50_uncollapsed_trace_parity(engine, google50_streams):
    """The ISSUE acceptance path: the real-columns google_like_50 trace
    replays UNCOLLAPSED through every accelerator engine and bit-matches
    the event-driven oracle with truncated == 0."""
    kw = dict(L=8, K=16, Qcap=128, work_steps=32)
    res = run_policy_streams(google50_streams, policy="bfjs-mr",
                             engine=engine, **kw)
    ref = run_policy_streams(google50_streams, policy="bfjs-mr",
                             engine="reference", L=8)
    assert int(res.truncated) == 0 and int(res.dropped) == 0
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(res.occupancy),
                                  np.asarray(ref.occupancy))
    np.testing.assert_array_equal(np.asarray(res.departed),
                                  np.asarray(ref.departed))
    assert int(res.departed[-1]) > 0
