"""Crash-safe chunked sweeps (DESIGN.md §9).

The resume contract: a sweep interrupted at ANY chunk boundary — cleanly
(``stop_after_chunks``) or by SIGKILL mid-process — and resumed with
``resume=True`` produces a trajectory BIT-IDENTICAL to the uninterrupted
run, on every ``PolicyResult`` field including the fault counters.
Property-tested over random kill schedules, plus a real ``SIGKILL``
delivered from inside the checkpoint writer in a subprocess.

Also pins the satellite contracts: checkpoints refuse to continue a
different sweep (policy / chunk / streams fingerprint), and
``ckpt.save``/``restore`` round-trips every engine-carry dtype
(int32/float32/bool planes, ``(T, R)`` occupancy) bit-exactly.
"""
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# deselected by the fast tier-1 lane (-m "not slow"); CI runs
# the full suite
pytestmark = pytest.mark.slow

# only the kill-schedule property test needs hypothesis — everything else
# in this module must run even where it is not installed
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.checkpoint import ckpt
from repro.core.engine import make_streams, run_policy_streams
from repro.core.engine import chunked
from repro.core.engine.bfjs_mr import run_bfjs_mr_streams

T = 240
FAULT = dict(fault_rate=0.02, repair_rate=0.3)


def _scalar_sampler(key, n):
    return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)


def _vec_sampler(key, n):
    return jax.random.uniform(key, (n, 2), minval=0.1, maxval=0.5)


#: policy -> (streams, engine config): small faulted sweeps so resume has
#: to carry retry planes, fault counters and ``up_last`` across boundaries.
def _case(policy):
    key = jax.random.PRNGKey(3)
    if policy == "bfjs-mr":
        streams = make_streams(key, 0.6, 0.5, _vec_sampler, L=4, K=3,
                               A_max=4, horizon=T, num_resources=2, **FAULT)
        return streams, dict(L=4, K=3, Qcap=32, A_max=4)
    streams = make_streams(key, 0.6, 0.5, _scalar_sampler, L=4, K=3,
                           A_max=4, horizon=T, **FAULT)
    cfg = dict(L=4, K=3, Qcap=32, A_max=4)
    if policy == "vqs":
        cfg["J"] = 4
    return streams, cfg


@pytest.fixture(scope="module", params=["bfjs", "vqs", "bfjs-mr"])
def case(request):
    policy = request.param
    streams, cfg = _case(policy)
    full = run_policy_streams(streams, policy=policy, engine="scan", **cfg)
    return policy, streams, cfg, full


def _assert_bitmatch(res, full, msg):
    for f in full._fields:
        a, b = np.asarray(getattr(res, f)), np.asarray(getattr(full, f))
        assert a.shape == b.shape and a.dtype == b.dtype, (msg, f)
        np.testing.assert_array_equal(a, b, err_msg=f"{msg}: field {f!r}")


# ---------------------------------------------------------------------------
# interrupt-and-resume == straight-through
# ---------------------------------------------------------------------------
def test_kill_at_boundary_and_resume_bitmatch(case, tmp_path):
    policy, streams, cfg, full = case
    d = str(tmp_path)
    part = run_policy_streams(streams, policy=policy, engine="scan",
                              checkpoint_dir=d, chunk=60,
                              stop_after_chunks=2, **cfg)
    assert part.queue_len.shape[0] == 120   # 2 of 4 chunks ran
    res = run_policy_streams(streams, policy=policy, engine="scan",
                             checkpoint_dir=d, chunk=60, resume=True, **cfg)
    assert int(full.preempted) > 0          # resume crossed real fault state
    _assert_bitmatch(res, full, f"{policy}: resumed != straight-through")
    # resuming a FINISHED sweep returns the stored result, runs nothing
    res2 = run_policy_streams(streams, policy=policy, engine="scan",
                              checkpoint_dir=d, chunk=60, resume=True, **cfg)
    _assert_bitmatch(res2, full, f"{policy}: finished-resume")


_BFJS_STREAMS, _BFJS_CFG = _case("bfjs")


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="needs hypothesis")
def test_any_kill_schedule_resumes_bitexact(tmp_path_factory):
    """Property: for ANY chunk length (ragged tail included) and ANY
    schedule of interruptions, chaining interrupted runs with resume=True
    reproduces the uninterrupted trajectory bit-for-bit."""

    @settings(max_examples=8, deadline=None, derandomize=True)
    @given(chunk=st.sampled_from([30, 50, 60, 80]),
           kills=st.lists(st.integers(min_value=1, max_value=3), min_size=1,
                          max_size=3))
    def prop(chunk, kills):
        _check_kill_schedule(chunk, kills, tmp_path_factory)

    prop()


def _check_kill_schedule(chunk, kills, tmp_path_factory):
    full = run_policy_streams(_BFJS_STREAMS, policy="bfjs", engine="scan",
                              **_BFJS_CFG)
    d = str(tmp_path_factory.mktemp("kills"))
    run_policy_streams(_BFJS_STREAMS, policy="bfjs", engine="scan",
                       checkpoint_dir=d, chunk=chunk,
                       stop_after_chunks=kills[0], **_BFJS_CFG)
    for k in kills[1:]:
        run_policy_streams(_BFJS_STREAMS, policy="bfjs", engine="scan",
                           checkpoint_dir=d, chunk=chunk, resume=True,
                           stop_after_chunks=k, **_BFJS_CFG)
    res = run_policy_streams(_BFJS_STREAMS, policy="bfjs", engine="scan",
                             checkpoint_dir=d, chunk=chunk, resume=True,
                             **_BFJS_CFG)
    _assert_bitmatch(res, full,
                     f"chunk={chunk} kills={kills}: resume diverged")


_CHILD = """
import os, signal, sys
import jax
import repro.core.engine.chunked as chunked
from repro.core.engine import make_streams, run_policy_streams

def sampler(key, n):
    return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)

streams = make_streams(jax.random.PRNGKey(3), 0.6, 0.5, sampler, L=4, K=3,
                       A_max=4, horizon=240, fault_rate=0.02,
                       repair_rate=0.3)
_real, _calls = chunked._save_step, 0

def _killing_save(*args, **kwargs):
    global _calls
    _real(*args, **kwargs)
    _calls += 1
    if _calls >= 2:
        os.kill(os.getpid(), signal.SIGKILL)

chunked._save_step = _killing_save
run_policy_streams(streams, policy="bfjs", engine="scan",
                   checkpoint_dir=sys.argv[1], chunk=60, L=4, K=3, Qcap=32,
                   A_max=4)
sys.exit("survived past the kill point")
"""


def test_sigkill_mid_sweep_then_resume(tmp_path):
    """A real SIGKILL delivered inside the checkpoint writer (no cleanup,
    no atexit): the surviving checkpoints resume to the exact
    straight-through trajectory."""
    streams, cfg = _BFJS_STREAMS, _BFJS_CFG
    full = run_policy_streams(streams, policy="bfjs", engine="scan", **cfg)
    d = str(tmp_path)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _CHILD, d], env=env,
                          capture_output=True, text=True)
    assert proc.returncode == -signal.SIGKILL, (proc.returncode,
                                                proc.stderr[-2000:])
    assert ckpt.latest_step(d) == 2          # died right after save #2
    res = run_policy_streams(streams, policy="bfjs", engine="scan",
                             checkpoint_dir=d, chunk=60, resume=True, **cfg)
    _assert_bitmatch(res, full, "post-SIGKILL resume diverged")


# ---------------------------------------------------------------------------
# resume validation: never continue a different sweep
# ---------------------------------------------------------------------------
def test_resume_refuses_mismatched_sweep(tmp_path):
    streams, cfg = _BFJS_STREAMS, _BFJS_CFG
    d = str(tmp_path)
    run_policy_streams(streams, policy="bfjs", engine="scan",
                       checkpoint_dir=d, chunk=60, stop_after_chunks=1,
                       **cfg)
    with pytest.raises(ValueError, match="different sweep"):
        run_policy_streams(streams, policy="bfjs", engine="scan",
                           checkpoint_dir=d, chunk=80, resume=True, **cfg)
    other = streams._replace(sizes=streams.sizes * 0.5)
    with pytest.raises(ValueError, match="different sweep"):
        run_policy_streams(other, policy="bfjs", engine="scan",
                           checkpoint_dir=d, chunk=60, resume=True, **cfg)
    assert chunked.streams_fingerprint(other) \
        != chunked.streams_fingerprint(streams)
    # dropping the fault plane is a different sweep too
    with pytest.raises(ValueError, match="different sweep"):
        run_policy_streams(streams._replace(up=None), policy="bfjs",
                           engine="scan", checkpoint_dir=d, chunk=60,
                           resume=True, **cfg)


def test_chunked_rejects_bad_usage(tmp_path):
    streams, cfg = _BFJS_STREAMS, _BFJS_CFG
    with pytest.raises(ValueError, match='engine="scan"'):
        run_policy_streams(streams, policy="bfjs", engine="pallas",
                           chunk=60, **cfg)
    with pytest.raises(ValueError, match="chunk"):
        run_policy_streams(streams, policy="bfjs", engine="scan",
                           checkpoint_dir=str(tmp_path), **cfg)
    with pytest.raises(ValueError, match="chunk must be positive"):
        chunked.run_chunked(streams, policy="bfjs", chunk=0, **cfg)
    with pytest.raises(ValueError, match="resume=True needs"):
        chunked.run_chunked(streams, policy="bfjs", chunk=60, resume=True,
                            **cfg)
    with pytest.raises(ValueError, match="no stateful scan engine"):
        chunked.run_chunked(streams, policy="nope", chunk=60, **cfg)
    with pytest.raises(ValueError, match="nothing to run"):
        chunked.run_chunked(streams, policy="bfjs", chunk=60,
                            stop_after_chunks=0, **cfg)


# ---------------------------------------------------------------------------
# satellite: checkpoint round-trips of engine-carry dtypes
# ---------------------------------------------------------------------------
def test_ckpt_round_trips_engine_carry_bitexact(case, tmp_path):
    """The full scan carry (int32 grids, float32 planes, the bool
    ``up_last`` lane) and the partial PolicyResult survive
    ``ckpt.save``/``_load_step`` with dtype and bits intact."""
    policy, streams, cfg, full = case
    if policy == "bfjs-mr":
        res, state = run_bfjs_mr_streams(streams, capacity=(1.0, 1.0),
                                         return_state=True, **cfg)
    else:
        from repro.core.engine.bfjs import run_bfjs_streams
        from repro.core.engine.vqs import run_vqs_streams
        runner = run_vqs_streams if policy == "vqs" else run_bfjs_streams
        res, state = runner(streams, return_state=True, **cfg)
    dtypes = {np.dtype(a.dtype) for a in state}
    assert {np.dtype(np.int32), np.dtype(bool)} <= dtypes, dtypes
    ckpt.save(str(tmp_path), 1, {"state": state, "partial": res})
    state2, res2 = chunked._load_step(str(tmp_path), 1)
    assert len(state2) == len(state)
    for i, (a, b) in enumerate(zip(state, state2)):
        assert a.dtype == b.dtype and a.shape == b.shape, (policy, i)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{policy}: carry leaf {i}")
    _assert_bitmatch(res2, res, f"{policy}: PolicyResult round-trip")


def test_ckpt_round_trips_T_R_occupancy_plane(tmp_path):
    """The (T, R) float32 occupancy plane of a multi-resource result —
    restore via ``like`` pytree is bit-exact, dtype preserved."""
    streams, cfg = _case("bfjs-mr")
    res = run_policy_streams(streams, policy="bfjs-mr", engine="scan",
                             **cfg)
    assert res.occupancy.shape == (T, 2)
    assert res.occupancy.dtype == jnp.float32
    ckpt.save(str(tmp_path), 7, res)
    like = jax.tree.map(jnp.zeros_like, res)
    back = ckpt.restore(str(tmp_path), 7, like)
    _assert_bitmatch(back, res, "(T, R) occupancy round-trip")


def test_ckpt_atomicity_layout(tmp_path):
    """tmp-then-rename: a completed save leaves no tmp droppings, and the
    step directory holds the npz + manifest pair."""
    ckpt.save(str(tmp_path), 3, {"x": jnp.arange(4, dtype=jnp.int32)})
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000003"]
    inner = sorted(os.listdir(tmp_path / "step_00000003"))
    assert inner == ["arrays.npz", "manifest.json"]
