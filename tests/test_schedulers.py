"""Scheduler invariants (hypothesis) + behavioural specifics."""
import numpy as np
import pytest

# deselected by the fast tier-1 lane (-m "not slow"); CI runs
# the full suite
pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (BFJ, BFJS, BFS, FIFOFF, VQS, Discrete, MaxWeight,
                        ServiceModel, Uniform, VQSBF, simulate)


def mk_policies(J=4, types=None):
    pol = [BFJS(), BFJ(), BFS(), FIFOFF(), VQS(J=J), VQSBF(J=J)]
    if types is not None:
        pol.append(MaxWeight(types))
    return pol


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4),
       st.floats(0.05, 0.9), st.floats(0.1, 0.95))
def test_invariants_random_workloads(seed, L, lam, lo_frac):
    """Capacity constraints + job conservation for every scheduler."""
    lo = 0.05 + 0.6 * lo_frac
    dist = Uniform(lo, min(lo + 0.3, 1.0))
    svc = ServiceModel("geometric", 20.0)
    for policy in mk_policies():
        res = simulate(policy, L=L, lam=lam, dist=dist, service=svc,
                       horizon=400, seed=seed, check_invariants=True)
        in_service = res.arrived - res.departed - res.final_queue
        assert in_service >= 0
        assert 0.0 <= res.utilization <= 1.0


def test_bfjs_packs_exact_fit():
    """0.4 + 0.6 must share one server under Best-Fit."""
    dist = Discrete([0.4, 0.6], [0.5, 0.5])
    svc = ServiceModel("geometric", 50.0)
    res = simulate(BFJS(), L=1, lam=0.03, dist=dist, service=svc,
                   horizon=20_000, seed=3, check_invariants=True)
    # supportable iff packing works (rho = 0.03*50 = 1.5 < rho* = 2)
    assert res.final_queue < 50
    assert res.departed > 0.95 * (res.arrived - 50)


def test_fifo_head_of_line_blocking():
    """FIFO-FF cannot reorder: a 0.9 job at HOL starves 0.1 jobs even when
    capacity is available; BF-J/S does not."""
    dist = Discrete([0.1, 0.9], [0.5, 0.5])
    svc = ServiceModel("geometric", 100.0)
    fifo = simulate(FIFOFF(), L=2, lam=0.028, dist=dist, service=svc,
                    horizon=30_000, seed=1)
    bf = simulate(BFJS(), L=2, lam=0.028, dist=dist, service=svc,
                  horizon=30_000, seed=1)
    assert bf.mean_queue_tail < fifo.mean_queue_tail


def test_vqs_respects_reservation():
    """Under config e1 + k e_j, non-type-1 jobs use at most 1/3 capacity."""
    dist = Discrete([0.6, 0.3], [0.5, 0.5])
    svc = ServiceModel("geometric", 30.0)
    res = simulate(VQS(J=3), L=2, lam=0.08, dist=dist, service=svc,
                   horizon=5000, seed=5, check_invariants=True)
    assert res.utilization > 0.2  # it does schedule


def test_maxweight_oracle_stable_on_finite_types():
    dist = Discrete([0.4, 0.6], [0.5, 0.5])
    svc = ServiceModel("geometric", 100.0)
    res = simulate(MaxWeight([0.4, 0.6]), L=1, lam=0.018, dist=dist,
                   service=svc, horizon=40_000, seed=2,
                   check_invariants=True)
    # rho = 1.8 < rho* = 2 -> stable
    assert res.final_queue < 120


@pytest.mark.parametrize("policy_cls", [BFJS, FIFOFF])
def test_heterogeneous_capacities(policy_cls):
    from repro.core.quantize import RES
    caps = np.array([RES, RES // 2, RES // 4], dtype=np.int64)
    dist = Uniform(0.05, 0.45)
    svc = ServiceModel("geometric", 25.0)
    res = simulate(policy_cls(), L=3, lam=0.1, dist=dist, service=svc,
                   horizon=2000, seed=0, capacities=caps,
                   check_invariants=True)
    assert res.departed > 0


def test_determinism():
    dist = Uniform(0.1, 0.9)
    svc = ServiceModel("geometric", 50.0)
    a = simulate(BFJS(), L=3, lam=0.1, dist=dist, service=svc,
                 horizon=3000, seed=42)
    b = simulate(BFJS(), L=3, lam=0.1, dist=dist, service=svc,
                 horizon=3000, seed=42)
    assert (a.queue_lens == b.queue_lens).all()
    assert a.departed == b.departed
