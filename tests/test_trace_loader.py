"""core.trace loaders: one-shot ``load_trace_csv`` and the streaming
``iter_trace_csv`` (one shared row-parsing core), on the checked-in 50-row
and corrupted fixtures, plus the Google-2019 machine-events adapter."""
import os

import numpy as np
import pytest

from repro.core import Trace, load_trace_csv
from repro.core.trace import (iter_trace_csv, load_machine_events_csv,
                              scan_trace_maxima)
from repro.core.engine import run_policy_streams, streams_from_trace

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "google_like_50.csv")


def test_load_fixture_shapes_and_domains():
    trace = load_trace_csv(FIXTURE)
    assert isinstance(trace, Trace)
    assert len(trace) == 50
    assert trace.arrival_slots.dtype == np.int64
    assert int(trace.arrival_slots[0]) == 0        # re-based to slot 0
    assert (np.diff(trace.arrival_slots) >= 0).all()
    # normalized into the engines' (0, 1] job-size domain
    for plane in (trace.cpu, trace.mem):
        assert plane.min() > 0 and plane.max() <= 1.0
    assert plane.max() == 1.0                      # rescaled by column max
    assert (trace.durations >= 1).all()


def test_load_fixture_values_round_trip():
    """Spot-check the first data row of the fixture: job 4000 submits at
    t=2.13s with 11.75 cores / 6.94 GiB for 216.4s."""
    trace = load_trace_csv(FIXTURE, slot_seconds=1.0)
    raw = np.loadtxt(FIXTURE, delimiter=",", skiprows=1)
    assert np.isclose(trace.cpu[0], 11.75 / raw[:, 2].max())
    assert np.isclose(trace.mem[0], 6.94 / raw[:, 3].max())
    assert int(trace.durations[0]) == int(np.ceil(216.4))
    # coarser slots compress arrivals and durations consistently
    coarse = load_trace_csv(FIXTURE, slot_seconds=60.0)
    assert coarse.arrival_slots.max() < trace.arrival_slots.max()
    assert (coarse.durations >= 1).all()


def test_loaded_trace_feeds_engines():
    """Loader half of the real-trace-ingestion item: CSV -> Trace ->
    streams_from_trace(collapse=False) -> bfjs-mr scan engine."""
    trace = load_trace_csv(FIXTURE, slot_seconds=10.0)
    # pad the horizon past the longest possible backlog (sum of durations)
    # so every job departs inside the window
    pad = int(trace.durations.sum()) + 10
    streams = streams_from_trace(trace, collapse=False,
                                 horizon=int(trace.arrival_slots[-1]) + pad)
    assert streams.num_resources == 2
    res = run_policy_streams(streams, policy="bfjs-mr", engine="scan",
                             L=8, K=16, Qcap=128, work_steps=32)
    ref = run_policy_streams(streams, policy="bfjs-mr", engine="reference",
                             L=8)
    assert int(res.truncated) == 0 and int(res.dropped) == 0
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  np.asarray(ref.queue_len))
    assert int(res.departed[-1]) == 50      # every job eventually served


def test_loader_job_id_optional_and_normalize_false_strict(tmp_path):
    p = tmp_path / "fractions.csv"
    p.write_text("submit_time,cpu,mem,duration\n"      # no job_id column
                 "0.0,0.25,0.5,10\n3.0,0.5,0.125,5\n")
    trace = load_trace_csv(p, normalize=False)
    assert len(trace) == 2
    np.testing.assert_allclose(trace.cpu, [0.25, 0.5])
    # absolute units under normalize=False must be rejected, not saturated
    with pytest.raises(ValueError, match="normalize"):
        load_trace_csv(FIXTURE, normalize=False)


def test_loader_error_paths(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("job_id,submit_time,cpu\n1,0.0,0.5\n")
    with pytest.raises(ValueError, match="no column for 'mem'"):
        load_trace_csv(p)
    p2 = tmp_path / "empty.csv"
    p2.write_text("")
    with pytest.raises(ValueError, match="empty trace"):
        load_trace_csv(p2)
    p3 = tmp_path / "norows.csv"
    p3.write_text("job_id,submit_time,cpu,mem,duration\n")
    with pytest.raises(ValueError, match="no usable rows"):
        load_trace_csv(p3)
    p4 = tmp_path / "badrow.csv"
    p4.write_text("job_id,submit_time,cpu,mem,duration\n1,x,0.5,0.5,10\n")
    # strict: first malformed row raises, naming file and 1-based row number
    with pytest.raises(ValueError, match=r"badrow\.csv:2: bad row"):
        load_trace_csv(p4, strict=True)
    # default: the row is skipped — leaving zero usable rows, and the
    # error says how many were dropped
    with pytest.raises(ValueError,
                       match=r"no usable rows \(1 malformed row"):
        load_trace_csv(p4)


CORRUPT = os.path.join(os.path.dirname(__file__), "data",
                       "google_like_corrupt.csv")


def test_loader_skips_and_counts_malformed_rows():
    """Pinned corrupted fixture: 10 good rows interleaved with 6 malformed
    ones (unparseable, NaN, inf, negative size, zero duration, backwards
    submit time).  Default mode skips-and-counts every one; the surviving
    rows match the clean subset exactly."""
    with pytest.warns(UserWarning, match="skipped 6 malformed"):
        trace = load_trace_csv(CORRUPT, normalize=False)
    assert trace.skipped == 6
    assert len(trace) == 10
    # the good rows survive untouched and stay slot-sorted
    assert (np.diff(trace.arrival_slots) >= 0).all()
    assert trace.cpu.min() > 0 and trace.mem.min() > 0
    assert (trace.durations >= 1).all()
    assert np.isfinite(trace.cpu).all() and np.isfinite(trace.mem).all()


@pytest.mark.parametrize("bad,why", [
    ("9,x,0.5,0.5,10", "unparseable"),
    ("9,6.0,nan,0.4,12", "non-finite"),
    ("9,6.0,0.3,inf,9", "non-finite"),
    ("9,6.0,-0.2,0.3,5", "non-positive resource"),
    ("9,6.0,0.0,0.0,5", "non-positive resource"),
    ("9,6.0,0.4,0.2,0", "non-positive duration"),
    ("9,1.0,0.3,0.3,7", "non-monotone submit time"),
])
def test_loader_strict_names_first_bad_row(tmp_path, bad, why):
    """strict=True raises on the FIRST malformed row, naming the file, the
    1-based line number and the reason."""
    p = tmp_path / "strict.csv"
    p.write_text("job_id,submit_time,cpu,mem,duration\n"
                 "1,5.0,0.25,0.5,10\n"          # good row, line 2
                 f"{bad}\n"                      # malformed row, line 3
                 "2,7.0,0.5,0.125,5\n")
    with pytest.raises(ValueError,
                       match=rf"strict\.csv:3: bad row \({why}"):
        load_trace_csv(p, strict=True)
    # default mode on the same file: skip the one bad row, keep the rest
    with pytest.warns(UserWarning, match="skipped 1 malformed"):
        trace = load_trace_csv(p, normalize=False)
    assert trace.skipped == 1 and len(trace) == 2
    # shared row-parsing core: the streaming reader rejects the exact
    # same row, additionally naming the chunk it fell in
    with pytest.raises(ValueError,
                       match=rf"strict\.csv:3 \(chunk 1\): bad row \({why}"):
        list(iter_trace_csv(p, chunk_rows=1, strict=True,
                            normalize=False))


# ---------------------------------------------------------------------------
# iter_trace_csv: the streaming reader
# ---------------------------------------------------------------------------

def _concat(chunks):
    return Trace(
        np.concatenate([c.arrival_slots for c in chunks]),
        np.concatenate([c.cpu for c in chunks]),
        np.concatenate([c.mem for c in chunks]),
        np.concatenate([c.durations for c in chunks]),
        skipped=sum(c.skipped for c in chunks))


@pytest.mark.parametrize("chunk_rows", [1, 7, 50, 200])
def test_iter_matches_one_shot_via_two_pass_maxima(chunk_rows):
    """The two-pass recipe (scan_trace_maxima -> iter_trace_csv) is
    bit-identical to load_trace_csv(normalize=True), any chunking."""
    one = load_trace_csv(FIXTURE)
    cpu_cap, mem_cap = scan_trace_maxima(FIXTURE)
    chunks = list(iter_trace_csv(FIXTURE, chunk_rows=chunk_rows,
                                 cpu_capacity=cpu_cap,
                                 mem_capacity=mem_cap))
    assert all(len(c) <= chunk_rows for c in chunks)
    cat = _concat(chunks)
    assert len(cat) == len(one) == 50
    for f in ("arrival_slots", "cpu", "mem", "durations"):
        np.testing.assert_array_equal(getattr(cat, f), getattr(one, f))


def test_iter_corrupt_fixture_same_accounting_as_one_shot():
    """The corrupt fixture exercises BOTH readers through the one shared
    parsing core: same rows kept, same rows skipped, same summary."""
    with pytest.warns(UserWarning, match="skipped 6 malformed"):
        one = load_trace_csv(CORRUPT, normalize=False)
    with pytest.warns(UserWarning, match="skipped 6 malformed"):
        chunks = list(iter_trace_csv(CORRUPT, chunk_rows=3,
                                     normalize=False))
    cat = _concat(chunks)
    assert cat.skipped == one.skipped == 6
    assert len(cat) == len(one) == 10
    for f in ("arrival_slots", "cpu", "mem", "durations"):
        np.testing.assert_array_equal(getattr(cat, f), getattr(one, f))


def test_iter_constant_memory_contract_and_errors(tmp_path):
    # fractions <= 1 stream fine without capacities ...
    p = tmp_path / "frac.csv"
    p.write_text("submit_time,cpu,mem,duration\n"
                 "0.0,0.25,0.5,10\n3.0,0.5,0.125,5\n")
    chunks = list(iter_trace_csv(p, chunk_rows=1))
    assert len(chunks) == 2 and len(chunks[0]) == 1
    # ... but absolute units need explicit divisors: a streaming reader
    # cannot see the global column maxima
    with pytest.raises(ValueError, match="cannot normalize by global"):
        list(iter_trace_csv(FIXTURE, chunk_rows=10))
    with pytest.raises(ValueError, match="passed together"):
        list(iter_trace_csv(p, chunk_rows=1, cpu_capacity=2.0))
    with pytest.raises(ValueError, match="chunk_rows"):
        list(iter_trace_csv(p, chunk_rows=0))
    p2 = tmp_path / "norows.csv"
    p2.write_text("submit_time,cpu,mem,duration\n")
    with pytest.raises(ValueError, match="no usable rows"):
        list(iter_trace_csv(p2, chunk_rows=1))


# ---------------------------------------------------------------------------
# Google-2019 machine-events adapter
# ---------------------------------------------------------------------------

_MACHINE_CSV = ("time,machine_id,type,cpus,memory\n"
                "0,70,1,16,64\n"          # ADD the big machine
                "0,71,1,8,32\n"           # ADD a half-size one
                "50,71,2,,\n"             # REMOVE 71
                "80,71,1,8,32\n"          # it comes back
                "90,70,3,16,128\n")       # UPDATE: 70 grows memory


def test_machine_events_capacities_and_schedule(tmp_path):
    p = tmp_path / "machines.csv"
    p.write_text(_MACHINE_CSV)
    me = load_machine_events_csv(p)
    assert me.num_servers == 2
    np.testing.assert_array_equal(me.machine_ids, [70, 71])
    # per-machine capacity = max over its ADD/UPDATE events
    np.testing.assert_array_equal(me.cpu_capacity, [16.0, 8.0])
    np.testing.assert_array_equal(me.mem_capacity, [128.0, 32.0])
    assert me.events == [(0, 0, True), (0, 1, True), (50, 1, False),
                         (80, 1, True), (90, 0, True)]
    # the events feed the engines' fault plane directly
    from repro.core.engine import fault_plane_from_events
    up = np.asarray(fault_plane_from_events(me.events, 100,
                                            me.num_servers))
    assert up[49, 1] and not up[50, 1] and up[80, 1]
    assert up[:, 0].all()


def test_machine_events_drive_iter_normalization(tmp_path):
    """machine_events= normalizes by FLEET max capacity: a full request of
    the biggest machine maps to 1.0."""
    p = tmp_path / "machines.csv"
    p.write_text(_MACHINE_CSV)
    me = load_machine_events_csv(p)
    t = tmp_path / "trace.csv"
    t.write_text("submit_time,cpu,mem,duration\n"
                 "0.0,16,128,10\n"          # the whole big machine
                 "1.0,4,32,5\n")
    chunks = list(iter_trace_csv(t, chunk_rows=10, machine_events=me))
    cat = _concat(chunks)
    np.testing.assert_allclose(cat.cpu, [1.0, 0.25])
    np.testing.assert_allclose(cat.mem, [1.0, 0.25])
    with pytest.raises(ValueError, match="not both"):
        list(iter_trace_csv(t, chunk_rows=1, machine_events=me,
                            cpu_capacity=1.0, mem_capacity=1.0))


def test_machine_events_error_paths(tmp_path):
    p = tmp_path / "bad_machines.csv"
    p.write_text("time,machine_id,type,cpus,memory\n"
                 "0,1,9,4,8\n")             # unknown event type
    with pytest.raises(ValueError, match="no usable rows"):
        load_machine_events_csv(p)
    with pytest.raises(ValueError, match="unknown event type 9"):
        load_machine_events_csv(p, strict=True)
    p2 = tmp_path / "removed_only.csv"
    p2.write_text("time,machine_id,type,cpus,memory\n"
                  "0,5,2,,\n")
    with pytest.raises(ValueError, match="only ever REMOVE"):
        load_machine_events_csv(p2)
